"""The unified experiment API: workload registry, scenario specs,
run artifacts, CLI discovery flags, and matrix-sweep equivalence."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import workloads
from repro.core.engines import get_engine, run_trace
from repro.core.experiment import (
    ENGINE_DEFAULTS,
    Experiment,
    RunArtifact,
    RunOptions,
    Scenario,
    build_engine,
    default_scenario,
    run_scenario,
)
from repro.core.sim import SimConfig, sweep_latency
from repro.core.workloads import (
    available_workloads,
    create_workload,
    get_workload,
)

US = 1e-6
GOLDEN = Path(__file__).parent.parent / "examples/scenarios/hash_index_2ssd.json"

# One cheap scenario reused across tests (hash-index is the fastest tracer).
SMALL = dict(n_keys=20_000, n_wl_ops=8_000, latencies_us=(0.1, 5),
             thread_candidates=(16, 24), n_ops=1500)


class TestWorkloadRegistry:
    def test_canonical_names_and_aliases(self):
        reg = available_workloads()
        assert reg["uniform"] is workloads.uniform
        assert reg["zipf"] is workloads.zipf
        assert reg["zipfian"] is workloads.zipf
        assert reg["gaussian"] is workloads.gaussian
        assert reg["normal"] is workloads.gaussian
        assert reg["graph-cache-leader"] is workloads.graph_cache_leader
        assert reg["gcl"] is workloads.graph_cache_leader

    def test_canonical_name_stamped(self):
        assert workloads.zipf.workload_name == "zipf"
        assert get_workload("gcl").workload_name == "graph-cache-leader"

    def test_underscore_lookup(self):
        assert get_workload("graph_cache_leader") is workloads.graph_cache_leader

    def test_unknown_raises_with_known_list(self):
        with pytest.raises(KeyError, match="unknown workload"):
            get_workload("nope")

    def test_create_matches_direct_call(self):
        via_registry = create_workload("zipf", 5000, 2000, exponent=0.9,
                                       read_write=(1, 0), seed=3)
        direct = workloads.zipf(5000, 2000, 0.9, (1, 0), seed=3)
        np.testing.assert_array_equal(via_registry.keys, direct.keys)
        np.testing.assert_array_equal(via_registry.is_write, direct.is_write)


class TestScenario:
    def test_json_round_trip(self):
        s = default_scenario("lsm", n_ssd=2)
        assert Scenario.from_json(s.to_json()) == s

    def test_mixture_latency_round_trip(self):
        s = Scenario(
            engine="lsm",
            latencies_us=(0.1, ((5, 0.9), (14, 0.099), (48, 0.001)), 10),
        )
        s2 = Scenario.from_json(s.to_json())
        assert s2 == s
        assert s2.latencies_us[1] == ((5, 0.9), (14, 0.099), (48, 0.001))
        # seconds conversion keeps the scalar-or-mixture shape
        lats = s2.latencies_sec()
        assert lats[0] == pytest.approx(0.1 * US)
        assert lats[1][1] == (pytest.approx(14 * US), 0.099)

    def test_list_inputs_normalize_to_tuples(self):
        # a hand-written JSON spec (lists everywhere) equals the
        # Python-constructed scenario (tuples everywhere)
        from_lists = Scenario(engine="lsm", latencies_us=[0.1, 5],
                              thread_candidates=[16, 24],
                              workload_kwargs={"read_write": [1, 0]})
        from_tuples = Scenario(engine="lsm", latencies_us=(0.1, 5),
                               thread_candidates=(16, 24),
                               workload_kwargs={"read_write": (1, 0)})
        assert from_lists == from_tuples

    def test_unknown_field_rejected(self):
        with pytest.raises(ValueError, match="unknown Scenario field"):
            Scenario.from_dict({"engine": "lsm", "lateencies_us": [1]})

    def test_empty_axes_rejected(self):
        with pytest.raises(ValueError, match="non-empty"):
            Scenario(engine="lsm", latencies_us=())
        with pytest.raises(ValueError, match="non-empty"):
            Scenario(engine="lsm", thread_candidates=[])
        with pytest.raises(ValueError, match="n_ssd"):
            Scenario(engine="lsm", n_ssd=0)
        with pytest.raises(ValueError, match="n_ops"):
            Scenario(engine="lsm", n_ops=0)

    def test_workload_defaults_resolve_from_pairing(self):
        s = Scenario(engine="rocksdb-like")   # alias, no workload named
        wname, wkw = s.resolved_workload()
        assert s.canonical_engine == "lsm"
        assert (wname, wkw["exponent"]) == ("zipf", 0.99)
        # explicit workload wins outright
        s = Scenario(engine="lsm", workload="gcl")
        assert s.resolved_workload()[0] == "graph-cache-leader"

    def test_engine_pairings_cover_registry(self):
        for engine in ("tree-index", "lsm", "two-tier-cache", "hash-index",
                       "slab-cache"):
            assert engine in ENGINE_DEFAULTS
            kw, wname, wkw = ENGINE_DEFAULTS[engine]
            assert get_workload(wname)  # name resolves

    def test_switch_hop_only_with_multiple_ssds(self):
        one = default_scenario("hash-index", n_ssd=1).sim_config()
        two = default_scenario("hash-index", n_ssd=2).sim_config()
        assert one.L_switch == 0.0
        assert two.L_switch == pytest.approx(0.3 * US)

    def test_host_spec_round_trips_and_reaches_sim_config(self):
        """n_cores / T_lock_us are part of the device spec: they survive
        the JSON round trip and land in SimConfig (T_lock in seconds)."""
        s = Scenario(engine="lsm", n_cores=4, T_lock_us=0.1)
        s2 = Scenario.from_json(s.to_json())
        assert s2 == s and s2.n_cores == 4 and s2.T_lock_us == 0.1
        cfg = s2.sim_config()
        assert cfg.n_cores == 4
        assert cfg.T_lock == pytest.approx(0.1 * US)
        # defaults stay single-core / lock-free
        base = Scenario(engine="lsm").sim_config()
        assert base.n_cores == 1 and base.T_lock == 0.0

    def test_host_spec_validation(self):
        with pytest.raises(ValueError, match="n_cores"):
            Scenario(engine="lsm", n_cores=0)
        with pytest.raises(ValueError, match="T_lock_us"):
            Scenario(engine="lsm", T_lock_us=-0.1)
        from repro.core.sim import SimConfig

        with pytest.raises(ValueError, match="n_cores"):
            SimConfig(n_cores=0)
        with pytest.raises(ValueError, match="n_threads"):
            SimConfig(n_threads=0)
        with pytest.raises(ValueError, match="T_lock"):
            SimConfig(T_lock=-1.0)


class TestGoldenScenario:
    def test_file_matches_default_scenario(self):
        s = Scenario.from_json(GOLDEN.read_text())
        assert s == default_scenario("hash-index", n_ssd=2,
                                     name="hash_index_2ssd")

    def test_file_is_valid_json_with_canonical_names(self):
        d = json.loads(GOLDEN.read_text())
        assert d["engine"] == "hash-index"
        assert d["workload"] == "uniform"


class TestRunArtifact:
    @pytest.fixture(scope="class")
    def artifact(self):
        return run_scenario(default_scenario("hash-index", n_ssd=2, **SMALL))

    def test_fields(self, artifact):
        assert artifact.engine == "hash-index"
        assert artifact.workload == "uniform"
        assert artifact.S == pytest.approx(1.0)   # every get hits the SSD
        assert artifact.M > 0
        assert len(artifact.rows) == 2
        for row in artifact.rows:
            assert row.throughput > 0
            assert row.model_throughput > 0
            assert dict(row.per_thread).keys() == {16, 24}
            assert row.mean_op_latency_us is None   # not collected
        assert artifact.normalized()[0] == pytest.approx(1.0)

    def test_json_round_trip_is_equal(self, artifact):
        again = RunArtifact.from_json(artifact.to_json())
        assert again == artifact
        # live handles are process-local, not serialized
        assert again.points is None and again.trace_result is None
        assert artifact.points is not None

    def test_newer_schema_rejected(self, artifact):
        d = artifact.to_dict()
        d["schema_version"] = 99
        with pytest.raises(ValueError, match="schema_version"):
            RunArtifact.from_dict(d)

    def test_csv_export(self, artifact):
        lines = artifact.to_csv().strip().splitlines()
        assert lines[0].startswith("L_us,n_threads,throughput_ops")
        assert len(lines) == 1 + len(artifact.rows)
        first = lines[1].split(",")
        assert float(first[0]) == pytest.approx(0.1)
        assert float(first[4]) == pytest.approx(1.0)    # normalized base

    def test_op_params_round_trip(self, artifact):
        p = artifact.op_params()
        assert p.M == pytest.approx(artifact.M)
        assert p.T_mem == pytest.approx(artifact.T_mem_us * US)

    def test_model_column_respects_device_iops_cap(self):
        # hash-index on one 250 kIOPS SSD is IOPS-bound (S=1): the model
        # column must carry the Eq. 14 cap, not the uncapped curve
        capped = run_scenario(default_scenario("hash-index", n_ssd=1, **SMALL))
        assert capped.rows[0].model_throughput == pytest.approx(250e3)
        # the sim agrees the cap binds (sanity that the fix matters)
        assert capped.rows[0].throughput == pytest.approx(250e3, rel=0.05)
        # with two devices the aggregate cap (500k) no longer binds
        free = run_scenario(default_scenario("hash-index", n_ssd=2, **SMALL))
        assert free.rows[0].model_throughput > capped.rows[0].model_throughput
        # uncapped scenario: no R_io, pure probabilistic model
        un = run_scenario(default_scenario("hash-index", n_ssd=1, R_io=0.0,
                                           **SMALL))
        assert un.rows[0].model_throughput > 250e3

    def test_collect_latency_option(self):
        art = run_scenario(
            default_scenario("hash-index", n_ssd=2, **SMALL),
            RunOptions(collect_latency=True),
        )
        for row in art.rows:
            assert row.mean_op_latency_us is not None
            assert row.mean_op_latency_us > 0
        assert RunArtifact.from_json(art.to_json()) == art

    def test_mixture_rows_serialize(self):
        spec = dict(SMALL)
        spec["latencies_us"] = (0.1, ((5, 0.9), (14, 0.099), (48, 0.001)))
        art = run_scenario(default_scenario("hash-index", n_ssd=2, **spec))
        assert art.rows[1].L_us == ((5, 0.9), (14, 0.099), (48, 0.001))
        assert art.rows[1].mean_latency_us == pytest.approx(5.934)
        assert "Lmix" in art.rows[1].label()
        assert RunArtifact.from_json(art.to_json()) == art

    def test_run_options_cache_dir(self, tmp_path):
        sc = default_scenario("hash-index", n_ssd=2, **SMALL)
        a = run_scenario(sc, RunOptions(cache_dir=str(tmp_path)))
        n_cells = len(sc.latencies_us) * len(sc.thread_candidates)
        assert len(list(tmp_path.glob("*.json"))) == n_cells
        b = run_scenario(sc, RunOptions(cache_dir=str(tmp_path)))
        assert a == b


class TestMatrixEquivalence:
    """The acceptance criterion: Experiment.run() on the golden scenario
    reproduces the legacy matrix-sweep protocol cell for cell."""

    def test_golden_scenario_reproduces_manual_protocol(self):
        """Bit-for-bit against a hand-rolled pre-redesign sweep (engine +
        workload built by hand, device config + sweep_latency called
        directly) -- the guarantee is real, not shim-circular."""
        sc = Scenario.from_json(GOLDEN.read_text())
        art = Experiment(sc).run()

        cls = get_engine("hash-index")
        store = cls(100_000, seed=6)
        wl = workloads.uniform(100_000, 30_000, (1, 0), seed=2)
        tr = run_trace(store, wl)
        cfg = SimConfig(n_ssd=2, R_io=250e3, L_switch=0.3 * US, P=12, seed=7)
        pts = sweep_latency(cfg, tr.trace,
                            [l * US for l in (0.1, 1, 3, 5, 8, 10)],
                            (16, 24, 32, 48, 64), n_ops=5000)

        assert art.S == tr.io_per_op and art.M == tr.mem_per_op
        assert len(art.rows) == len(pts)
        for row, pt in zip(art.rows, pts):
            assert row.throughput == pt.throughput       # bit-for-bit
            assert row.n_threads == pt.n_threads
            assert dict(row.per_thread) == pt.per_thread

    def test_matrix_sweep_shim_delegates_identically(self):
        """benchmarks.common.matrix_sweep (the deprecation-era shim) and the
        public API return the same points for the same spec."""
        from benchmarks import common

        kw = dict(l_us_list=(0.1, 5), candidates=(16, 24), nk=20_000,
                  nops=8_000, n_ops=1500)
        tr, pts = common.matrix_sweep("hash-index", n_ssd=2, **kw)
        art = Experiment(default_scenario(
            "hash-index", n_ssd=2, latencies_us=(0.1, 5),
            thread_candidates=(16, 24), n_keys=20_000, n_wl_ops=8_000,
            n_ops=1500)).run()
        assert [p.throughput for p in pts.values()] == \
            [r.throughput for r in art.rows]
        assert tr.io_per_op == art.S

    def test_engine_defaults_shim_warns_with_migration_map(self):
        from benchmarks import common

        with pytest.warns(DeprecationWarning, match="migration map"):
            legacy = common.ENGINE_DEFAULTS
        kwargs, factory = legacy["lsm"]
        wl = factory(5000, 2000)
        direct = workloads.zipf(5000, 2000, 0.99, (1, 0), seed=3)
        np.testing.assert_array_equal(wl.keys, direct.keys)

    def test_legacy_mutation_registration_still_works(self):
        # pre-redesign engine-author pattern: mutate common.ENGINE_DEFAULTS
        # to pair a new (or existing) engine with a custom default workload
        from benchmarks import common

        with pytest.warns(DeprecationWarning):
            table = common.ENGINE_DEFAULTS
        saved = table["lsm"]
        try:
            table["lsm"] = (dict(), lambda nk, nops: workloads.uniform(
                nk, nops, (1, 0), seed=42))
            with pytest.warns(DeprecationWarning):
                assert common.ENGINE_DEFAULTS["lsm"][1] is table["lsm"][1]
            _, wl = common.build_engine("lsm", 5000, 2000)
            direct = workloads.uniform(5000, 2000, (1, 0), seed=42)
            np.testing.assert_array_equal(wl.keys, direct.keys)
            # ... and matrix_sweep honors the mutated pairing too (it ran
            # through common.build_engine pre-redesign)
            tr, pts = common.matrix_sweep("lsm", l_us_list=(0.1,),
                                          candidates=(16,), nk=5000,
                                          nops=2000, n_ops=400)
            tr_direct = run_trace(
                common.build_engine("lsm", 5000, 2000)[0], direct)
            assert tr.mem_per_op == tr_direct.mem_per_op
            assert tr.io_per_op == tr_direct.io_per_op
        finally:
            table["lsm"] = saved
        # restored table: matrix_sweep is back on the scenario path
        tr2, _ = common.matrix_sweep("lsm", l_us_list=(0.1,),
                                     candidates=(16,), nk=5000, nops=2000,
                                     n_ops=400)
        assert tr2.mem_per_op != tr.mem_per_op


class TestBuildEngine:
    def test_any_registry_name(self):
        store, wl = build_engine("hash_index", 5000, 2000)
        assert type(store).engine_name == "hash-index"
        assert wl.name == "uniform" and len(wl) == 2000

    def test_unknown_engine_lists_known(self):
        with pytest.raises(KeyError, match="unknown engine"):
            build_engine("nope")


class TestCLI:
    def _main(self, argv, capsys, monkeypatch):
        import benchmarks.run as run_mod

        monkeypatch.setattr("sys.argv", ["benchmarks.run", *argv])
        run_mod.main()
        return capsys.readouterr().out

    def test_list_engines_canonical_only(self, capsys, monkeypatch):
        out = self._main(["--list-engines"], capsys, monkeypatch).split()
        assert "tree-index" in out and "hash-index" in out
        assert "aerospike-like" not in out    # aliases omitted

    def test_list_workloads_canonical_only(self, capsys, monkeypatch):
        out = self._main(["--list-workloads"], capsys, monkeypatch).split()
        assert out == ["drifting-zipf", "gaussian", "graph-cache-leader",
                       "uniform", "zipf"]

    def test_scenario_flag_runs_spec(self, capsys, monkeypatch, tmp_path):
        spec = tmp_path / "tiny.json"
        spec.write_text(default_scenario(
            "hash-index", n_ssd=2, name="tiny", **SMALL).to_json())
        art_out = tmp_path / "artifact.json"
        out = self._main(["--scenario", str(spec), "--artifact",
                          str(art_out)], capsys, monkeypatch)
        assert "scenario/tiny/L0.1us" in out
        assert "scenario/tiny/summary" in out
        art = RunArtifact.from_json(art_out.read_text())
        assert art.scenario.name == "tiny" and len(art.rows) == 2

    def test_cores_flag_reaches_scenario(self, capsys, monkeypatch):
        """--cores N is device-spec sugar like --devices: it lands in the
        scenario (and so in every cell's SimConfig) and the CSV prefix."""
        import benchmarks.run as run_mod

        seen = {}

        def fake_run(scenario, *a, prefix=None, **kw):
            seen["scenario"], seen["prefix"] = scenario, prefix

        monkeypatch.setattr(run_mod, "run_scenario_cmd", fake_run)
        self._main(["--engine", "hash-index", "--cores", "2"],
                   capsys, monkeypatch)
        assert seen["scenario"].n_cores == 2
        assert seen["scenario"].sim_config().n_cores == 2
        assert seen["prefix"].endswith("/cores2")

    def test_cores_flag_validates(self, capsys, monkeypatch):
        with pytest.raises(SystemExit, match="--cores must be >= 1"):
            self._main(["--engine", "hash-index", "--cores", "0"],
                       capsys, monkeypatch)

    def test_bad_scenario_spec_exits_with_message(self, capsys, monkeypatch,
                                                  tmp_path):
        spec = tmp_path / "bad.json"
        spec.write_text('{"engine": "lsm", "bogus_field": 1}')
        with pytest.raises(SystemExit, match="bad scenario spec"):
            self._main(["--scenario", str(spec)], capsys, monkeypatch)

    def test_unknown_engine_in_spec_exits_with_known_list(self, capsys,
                                                          monkeypatch,
                                                          tmp_path):
        # engine resolution is lazy: the spec parses, the run must still
        # exit cleanly with the registry listing (like --engine does)
        spec = tmp_path / "unknown.json"
        spec.write_text('{"engine": "hash-idx"}')
        with pytest.raises(SystemExit, match="unknown engine"):
            self._main(["--scenario", str(spec)], capsys, monkeypatch)

    def test_missing_spec_file_exits_cleanly(self, capsys, monkeypatch):
        with pytest.raises(SystemExit, match="cannot read scenario spec"):
            self._main(["--scenario", "/no/such/spec.json"], capsys,
                       monkeypatch)

    def test_engine_sugar_artifact_uses_matrix_prefix(self, capsys,
                                                      monkeypatch, tmp_path):
        art_out = tmp_path / "a.json"
        import benchmarks.run as run_mod

        monkeypatch.setattr("sys.argv", [
            "benchmarks.run", "--engine", "hash_index", "--devices", "2",
            "--artifact", str(art_out)])
        monkeypatch.setattr(
            "repro.core.experiment.default_scenario",
            lambda engine, n_ssd=1, **kw: default_scenario(
                engine, n_ssd=n_ssd, **{**SMALL, **kw}))
        run_mod.main()
        err = capsys.readouterr().err
        assert "matrix/hash_index/ssd2/artifact" in err
