"""The columnar trace IR: roundtrips, validation, and vectorized summaries."""
import numpy as np
import pytest

from repro.core import workloads
from repro.core.engines import EngineTimes, LSMStore, Recorder, run_trace
from repro.core.trace_ir import CPU, MEM, POSTIO, PREIO, CompiledTrace, Op

US = 1e-6


@pytest.fixture(scope="module")
def lsm_trace():
    store = LSMStore(20_000)
    wl = workloads.zipf(20_000, 8_000, 0.99, (2, 1), seed=3)
    return run_trace(store, wl)


class TestCompiledTrace:
    def test_roundtrip_from_ops(self, lsm_trace):
        ops = lsm_trace.ops
        trace = CompiledTrace.from_ops(ops)
        assert trace.n_ops == len(ops)
        assert trace.to_ops() == ops

    def test_recorder_emits_columnar_directly(self):
        rec = Recorder(EngineTimes())
        rec.mem(3)
        rec.cpu(1e-7)
        rec.io()
        rec.end_op()
        rec.mem(1)
        rec.end_op()
        trace = rec.compile()
        assert trace.n_ops == 2
        assert trace.kinds.tolist() == [MEM, MEM, MEM, CPU, PREIO, POSTIO, MEM]
        assert trace.bounds.tolist() == [0, 6, 7]
        # the legacy row view matches the columns
        assert CompiledTrace.from_ops(rec.ops).to_ops() == trace.to_ops()

    def test_empty_op_padding(self):
        rec = Recorder(EngineTimes())
        rec.end_op()                    # engines never emit empty ops
        trace = rec.compile()
        assert trace.kinds.tolist() == [CPU]

    def test_validation(self):
        with pytest.raises(ValueError):
            CompiledTrace(np.array([0]), np.array([1.0]), np.array([0, 0, 1]))
        with pytest.raises(ValueError):
            CompiledTrace(np.array([0, 0]), np.array([1.0]), np.array([0, 2]))

    def test_arrays_immutable(self, lsm_trace):
        with pytest.raises(ValueError):
            lsm_trace.trace.kinds[0] = CPU

    def test_pickle_roundtrip_stays_immutable(self, lsm_trace):
        import pickle

        trace = lsm_trace.trace
        trace.as_lists()           # populate the cache; it must not ship
        clone = pickle.loads(pickle.dumps(trace))
        assert clone._lists is None
        assert clone.to_ops() == trace.to_ops()
        with pytest.raises(ValueError):
            clone.kinds[0] = CPU

    def test_counts_and_lists_cache(self, lsm_trace):
        trace = lsm_trace.trace
        counts = trace.counts()
        assert counts["MEM"] == int((trace.kinds == MEM).sum())
        assert trace.as_lists() is trace.as_lists()   # cached
        kinds, durs, starts, ends = trace.as_lists()
        assert len(kinds) == len(durs) == trace.n_subops
        assert len(starts) == len(ends) == trace.n_ops


def _yield_spans_reference(ops):
    """The pre-refactor row-oriented span computation (kvstore.op_params)."""
    span_sum = {MEM: 0.0, PREIO: 0.0, POSTIO: 0.0}
    span_n = {MEM: 0, PREIO: 0, POSTIO: 0}
    pending_cpu = 0.0
    last_yield = None
    for op in ops:
        for kind, dur in op.subops:
            if kind == CPU:
                pending_cpu += dur
                continue
            span_sum[kind] += dur + pending_cpu
            span_n[kind] += 1
            pending_cpu = 0.0
            last_yield = kind
    if pending_cpu > 0.0 and last_yield is not None:
        span_sum[last_yield] += pending_cpu
    return span_sum, span_n


class TestYieldSpans:
    def test_matches_row_oriented_reference(self, lsm_trace):
        ref_sum, ref_n = _yield_spans_reference(lsm_trace.ops)
        vec_sum, vec_n = lsm_trace.trace.yield_spans()
        assert vec_n == ref_n
        for kind in (MEM, PREIO, POSTIO):
            assert vec_sum[kind] == pytest.approx(ref_sum[kind], rel=1e-9)

    def test_trailing_cpu_folds_into_last_yield(self):
        ops = [Op(((MEM, 1.0), (CPU, 0.5))), Op(((CPU, 0.25), (PREIO, 2.0),
                                                 (POSTIO, 0.5), (CPU, 0.125)))]
        trace = CompiledTrace.from_ops(ops)
        span_sum, span_n = trace.yield_spans()
        ref_sum, ref_n = _yield_spans_reference(ops)
        assert span_n == ref_n
        for kind in (MEM, PREIO, POSTIO):
            assert span_sum[kind] == pytest.approx(ref_sum[kind], rel=1e-12)
        # CPU between yields folds forward, the final 0.125 folds backward
        assert span_sum[PREIO] == pytest.approx(2.0 + 0.5 + 0.25)
        assert span_sum[POSTIO] == pytest.approx(0.5 + 0.125)

    def test_op_params_matches_reference(self, lsm_trace):
        p = lsm_trace.op_params(None, P=12, T_sw=0.05 * US)
        ref_sum, ref_n = _yield_spans_reference(lsm_trace.ops)
        assert p.T_mem == pytest.approx(ref_sum[MEM] / ref_n[MEM], rel=1e-9)
        assert p.T_io_pre == pytest.approx(ref_sum[PREIO] / ref_n[PREIO],
                                           rel=1e-9)
        assert p.M == lsm_trace.mem_per_op
        assert p.S == pytest.approx(lsm_trace.io_per_op)
