"""The hash-index and slab-cache engines: registry round-trip, semantics,
and loop equivalence (the PR-3 engine-matrix acceptance criteria)."""
import dataclasses

import pytest

from repro.core import workloads
from repro.core.engines import (
    HashIndexStore,
    KVEngine,
    Recorder,
    SlabCacheStore,
    available_engines,
    create_engine,
    get_engine,
    run_trace,
)
from repro.core.sim import (
    SimConfig,
    simulate,
    simulate_compiled,
    sweep_latency,
    trace_source,
)
from repro.core.trace_ir import MEM, PREIO

US = 1e-6
NK = 30_000


@pytest.fixture(scope="module")
def hash_trace():
    store = HashIndexStore(NK, seed=6)
    wl = workloads.uniform(NK, 12_000, (1, 0), seed=2)
    return store, run_trace(store, wl)


@pytest.fixture(scope="module")
def slab_trace():
    store = SlabCacheStore(NK, seed=8)
    wl = workloads.zipf(NK, 12_000, 0.9, (3, 1), seed=8)
    return store, run_trace(store, wl)


class TestRegistryRoundTrip:
    @pytest.mark.parametrize("name,cls", [
        ("hash-index", HashIndexStore),
        ("open-addressing", HashIndexStore),
        ("slab-cache", SlabCacheStore),
        ("memcached-like", SlabCacheStore),
    ])
    def test_lookup(self, name, cls):
        assert get_engine(name) is cls
        assert name in available_engines()

    @pytest.mark.parametrize("name,canonical", [
        ("hash_index", "hash-index"),
        ("slab_cache", "slab-cache"),
        ("two_tier_cache", "two-tier-cache"),
        ("tree_index", "tree-index"),
    ])
    def test_cli_underscores_resolve(self, name, canonical):
        # get_engine normalizes underscores for every registered name
        assert get_engine(name) is get_engine(canonical)

    def test_canonical_name_stamped(self):
        assert HashIndexStore.engine_name == "hash-index"
        assert SlabCacheStore.engine_name == "slab-cache"
        # aliases resolve to the same canonical name
        assert get_engine("memcached-like").engine_name == "slab-cache"

    def test_create_and_protocol(self):
        for name in ("hash-index", "slab-cache"):
            store = create_engine(name, 500)
            assert isinstance(store, KVEngine)
            assert isinstance(store.stats(), dict)


class TestHashIndexSemantics:
    def test_all_keys_found_and_read_io(self):
        store = HashIndexStore(1000, seed=0)
        for k in range(0, 1000, 41):
            rec = Recorder(store.times)
            store.op(k, False, rec)
            kinds = rec.compile().kinds.tolist()
            assert MEM in kinds            # at least one probe hop
            assert PREIO in kinds          # the SSD value read

    def test_absent_key_no_io(self):
        store = HashIndexStore(1000, seed=0)
        rec = Recorder(store.times)
        store.op(5000, False, rec)         # key outside the loaded range
        assert PREIO not in rec.compile().kinds.tolist()

    def test_line_sharing_beats_per_probe_hops(self, hash_trace):
        store, tr = hash_trace
        st = store.stats()
        # probes per op exceed memory hops per op: probe runs share lines
        assert st["avg_probes"] > tr.mem_per_op
        assert tr.mem_per_op < 3.0
        assert tr.io_per_op == pytest.approx(1.0)   # read-only: one IO per get

    def test_trace_deterministic(self):
        wl = workloads.uniform(2000, 3000, (2, 1), seed=4)
        t1 = run_trace(HashIndexStore(2000, seed=3), wl)
        t2 = run_trace(HashIndexStore(2000, seed=3), wl)
        assert (t1.trace.kinds == t2.trace.kinds).all()
        assert (t1.trace.durs == t2.trace.durs).all()

    def test_bad_load_factor_rejected(self):
        with pytest.raises(ValueError, match="load_factor"):
            HashIndexStore(100, load_factor=1.5)


class TestSlabCacheSemantics:
    def test_hits_skip_io_misses_pay_it(self):
        store = SlabCacheStore(1000, seed=0)
        rec = Recorder(store.times)
        store.op(7, False, rec)            # cold miss: backing-store read
        assert PREIO in rec.compile().kinds.tolist()
        rec = Recorder(store.times)
        store.op(7, False, rec)            # now resident: pure memory op
        assert PREIO not in rec.compile().kinds.tolist()

    def test_eviction_is_per_class(self):
        store = SlabCacheStore(400, cache_bytes=16 * 1024, seed=0)
        rec = Recorder(store.times)
        for k in range(400):
            store.op(k, False, rec)
        for c, lru in enumerate(store.lru):
            assert len(lru) <= store.class_cap[c]

    def test_stats_shape(self, slab_trace):
        store, tr = slab_trace
        st = store.stats()
        assert set(st) == {"class_128B", "class_256B", "class_512B",
                           "class_1024B", "overall"}
        assert 0.0 < st["overall"] < 1.0
        # S reflects the miss ratio: cache engines do IO only on misses
        assert tr.io_per_op < 1.0


class TestLoopEquivalence:
    """Compiled-vs-generic equivalence on the new engines, including
    multi-SSD device configs (ISSUE-3 acceptance: within 2% per grid
    point; the loops are in fact bit-identical)."""

    CONFIGS = [
        dict(L_mem=5 * US, n_threads=40),
        dict(L_mem=8 * US, n_threads=56, n_ssd=2, R_io=100e3),
        dict(L_mem=1 * US, n_threads=24, n_ssd=3, R_io=80e3,
             L_switch=0.3 * US),
    ]

    @pytest.mark.parametrize("fixture", ["hash_trace", "slab_trace"])
    @pytest.mark.parametrize("kw", CONFIGS,
                             ids=[f"cfg{i}" for i in range(len(CONFIGS))])
    def test_bit_identical(self, request, fixture, kw):
        _, tr = request.getfixturevalue(fixture)
        cfg = SimConfig(seed=7, **kw)
        generic = simulate(cfg, trace_source(tr.ops), 2500)
        compiled = simulate_compiled(cfg, tr.trace, 2500)
        assert compiled.throughput == generic.throughput
        assert compiled.mem_stall_total == generic.mem_stall_total
        assert compiled.mem_accesses == generic.mem_accesses

    @pytest.mark.parametrize("fixture", ["hash_trace", "slab_trace"])
    def test_sweep_matches_generic_loop(self, request, fixture):
        """Every sweep_latency grid cell equals a fresh generic-loop run of
        the same (seeded) cell config -- stronger than the 2% criterion."""
        _, tr = request.getfixturevalue(fixture)
        cfg = SimConfig(P=12, seed=7)
        lats = [0.1 * US, 5 * US]
        cands = (24, 40)
        pts = sweep_latency(cfg, tr.trace, lats, cands, n_ops=2000)
        for L, pt in zip(lats, pts):
            for n, thr in pt.per_thread.items():
                legacy = simulate(
                    dataclasses.replace(cfg, L_mem=L, n_threads=n),
                    trace_source(tr.ops), 2000)
                rel = abs(thr - legacy.throughput) / legacy.throughput
                assert rel < 0.02
                assert thr == legacy.throughput   # actually bit-identical
