"""``tools/check_bench.py`` -- one table-driven validator, four schemas.

Contract: a well-formed measurement file of any schema in
``check_bench.SCHEMAS`` exits 0; a missing/mistyped field, a violated
invariant (unordered percentiles, achieved load outrunning offered,
node shares not summing to 1, a missing degraded node, index/artifact
disagreement), or a breached perf floor exits 1 with a
``check_bench: FAIL:`` message.  Fresh mode compares warm speedups for
the jax-grid schema and re-validates machine-independent invariants for
the rest.  The tool is stdlib-only, so the tests drive its real
``main()`` through ``sys.argv`` on tmp-path JSON fixtures.
"""
import copy
import importlib.util
import json
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent

spec = importlib.util.spec_from_file_location(
    "check_bench", ROOT / "tools" / "check_bench.py")
check_bench = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_bench)

HOST = {"platform": "test", "machine": "x", "cpu_count": 4}


# -- minimal valid documents, one per schema ---------------------------------

def grid_entry(name="default", cells=6, lats=3, threads=2, speedup=6.0):
    return {
        "name": name, "engine": "hash-index", "n_ssd": 1,
        "n_latencies": lats, "n_threads": threads, "cells": cells,
        "n_ops": 2000, "loop_s": 1.2, "loop_mode": "python",
        "jax_cold_s": 2.0, "jax_warm_s": 1.2 / speedup,
        "warm_speedup": speedup,
    }


def grid_doc(default_speedup=6.0):
    return {
        "schema": check_bench.SCHEMA, "host": HOST,
        "entries": [grid_entry(speedup=default_speedup)],
        "summary": {"default": {
            "cells": 6, "loop_s": 1.2,
            "jax_warm_s": 1.2 / default_speedup,
            "warm_speedup": default_speedup,
        }},
    }


def tail_entry(frac=0.5, offered=100_000.0, achieved=99_000.0,
               n_ops=100, missed=0):
    return {
        "name": "smoke", "engine": "hash-index", "L_us": 2.0,
        "n_threads": 8, "n_ops": n_ops, "offered_frac": frac,
        "offered_load": offered, "achieved_load": achieved,
        "p50_us": 20.0, "p90_us": 45.0, "p99_us": 110.0,
        "max_us": 300.0, "count": n_ops - missed, "missed": missed,
        "miss_rate": missed / n_ops, "source": "test",
    }


def tail_doc():
    return {
        "schema": check_bench.TAIL_SCHEMA, "host": HOST,
        "entries": [tail_entry(0.5, 100_000.0),
                    tail_entry(0.9, 180_000.0, 170_000.0)],
        "summary": {"smoke": {"capacity": 200_000.0,
                              "offered_fracs": [0.5, 0.9],
                              "n_points": 2}},
    }


def cluster_node(node=0, share=0.5, degraded=False, n_ops=50):
    return {
        "node": node, "share": share, "degraded": degraded,
        "n_ops": n_ops, "offered_load": 60_000.0,
        "achieved_load": 58_000.0, "count": n_ops, "missed": 0,
    }


def cluster_entry(name="degraded_node", migrate=False):
    return {
        "name": name, "engine": "hash-index", "backend": "loop",
        "n_nodes": 2, "L_us": 2.0, "n_threads": 16, "n_ops": 100,
        "migrate": migrate, "offered_frac": 0.6,
        "offered_load": 120_000.0, "achieved_load": 115_000.0,
        "p50_us": 25.0, "p90_us": 60.0, "p99_us": 140.0,
        "max_us": 400.0, "count": 100, "missed": 0, "miss_rate": 0.0,
        "source": "test",
        "nodes": [cluster_node(0, 0.5),
                  cluster_node(1, 0.5, degraded=(name == "degraded_node"))],
    }


def cluster_doc():
    agg = {"capacity": 200_000.0, "offered_frac": 0.6, "n_points": 1,
           "n_nodes": 2, "hottest_share": 0.5, "migrate": False}
    return {
        "schema": check_bench.CLUSTER_SCHEMA, "host": HOST,
        "entries": [cluster_entry("degraded_node"),
                    cluster_entry("hot_shard")],
        "summary": {
            "degraded_node": dict(agg, degraded_nodes=[1]),
            "hot_shard": dict(agg, degraded_nodes=[]),
        },
    }


def suite_row(threads=8, thr=100_000.0, nodes=None):
    r = {"n_threads": threads, "throughput": thr,
         "model_throughput": thr * 1.05}
    if nodes is not None:
        r["nodes"] = nodes
    return r


def suite_doc():
    nodes = [{"node": 0, "share": 0.6, "throughput": 60_000.0},
             {"node": 1, "share": 0.4, "throughput": 40_000.0}]
    return {
        "schema": check_bench.SUITE_SCHEMA, "suite": "scenarios",
        "backend": "loop", "host": HOST,
        "index": [
            {"scenario": "flat", "file": "flat.json",
             "engine": "hash-index", "workload": "uniform", "n_rows": 2,
             "arrival": "closed", "cluster_nodes": 0, "wall_s": 0.5},
            {"scenario": "fleet", "file": "fleet.json", "engine": "lsm",
             "workload": "zipf", "n_rows": 1, "arrival": "poisson",
             "cluster_nodes": 2, "wall_s": 0.9},
        ],
        "artifacts": {
            "flat": {"rows": [suite_row(), suite_row(16, 150_000.0)]},
            "fleet": {"rows": [suite_row(nodes=nodes)]},
        },
        "summary": {"n_scenarios": 2, "total_rows": 3,
                    "total_wall_s": 1.4},
    }


ALL_DOCS = {
    "grid": grid_doc, "tail": tail_doc, "cluster": cluster_doc,
    "suite": suite_doc,
}


@pytest.fixture
def write(tmp_path):
    def _write(doc, name="bench.json"):
        p = tmp_path / name
        p.write_text(json.dumps(doc))
        return str(p)
    return _write


def _run(monkeypatch, argv):
    monkeypatch.setattr("sys.argv", ["check_bench.py", *argv])
    try:
        check_bench.main()
    except SystemExit as e:
        if e.code in (None, 0):
            return 0
        return e.code if isinstance(e.code, int) else 1
    return 0


class TestSchemaTable:
    @pytest.mark.parametrize("kind", sorted(ALL_DOCS))
    def test_valid_doc_passes(self, kind, write, monkeypatch):
        assert _run(monkeypatch, [write(ALL_DOCS[kind]())]) == 0

    @pytest.mark.parametrize("kind", sorted(ALL_DOCS))
    def test_fresh_mode_accepts_itself(self, kind, write, monkeypatch):
        p = write(ALL_DOCS[kind]())
        assert _run(monkeypatch, ["--fresh", p, "--baseline", p]) == 0

    def test_unknown_schema_fails(self, write, monkeypatch):
        doc = grid_doc()
        doc["schema"] = "repro.nope/v1"
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_unreadable_file_fails(self, tmp_path, monkeypatch):
        assert _run(monkeypatch, [str(tmp_path / "missing.json")]) == 1

    def test_missing_host_fails(self, write, monkeypatch):
        doc = tail_doc()
        del doc["host"]
        assert _run(monkeypatch, [write(doc)]) == 1

    @pytest.mark.parametrize("kind", ["grid", "tail", "cluster"])
    def test_missing_entry_field_fails(self, kind, write, monkeypatch):
        doc = ALL_DOCS[kind]()
        del doc["entries"][0]["engine"]
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_bool_does_not_satisfy_numeric_field(self, write,
                                                 monkeypatch):
        doc = tail_doc()
        doc["entries"][0]["offered_load"] = True
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_fresh_schema_must_match_baseline(self, write, monkeypatch):
        base = write(grid_doc(), "base.json")
        fresh = write(tail_doc(), "fresh.json")
        assert _run(monkeypatch, ["--fresh", fresh,
                                  "--baseline", base]) == 1


class TestGridSchema:
    def test_cells_must_factor(self, write, monkeypatch):
        doc = grid_doc()
        doc["entries"][0]["cells"] = 7
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_default_floor(self, write, monkeypatch):
        assert _run(monkeypatch, [write(grid_doc(0.8))]) == 1

    def test_het_entry_needs_cohort_fields(self, write, monkeypatch):
        doc = grid_doc()
        doc["entries"].append(grid_entry(name="het"))
        doc["summary"]["het"] = dict(doc["summary"]["default"],
                                     mono_speedup=2.0)
        assert _run(monkeypatch, [write(doc)]) == 1    # het fields absent

    def test_regression_gate(self, write, monkeypatch):
        base = write(grid_doc(6.0), "base.json")
        ok = write(grid_doc(3.0), "ok.json")         # 2x slower: allowed
        bad = write(grid_doc(1.5), "bad.json")       # 4x slower: not
        assert _run(monkeypatch, ["--fresh", ok, "--baseline", base]) == 0
        assert _run(monkeypatch, ["--fresh", bad, "--baseline",
                                  base]) == 1
        assert _run(monkeypatch, ["--fresh", bad, "--baseline", base,
                                  "--max-regress", "10"]) == 0

    def test_disjoint_suites_fail_regression(self, write, monkeypatch):
        fresh_doc = grid_doc()
        fresh_doc["summary"] = {"other": fresh_doc["summary"]["default"]}
        fresh_doc["entries"][0]["name"] = "other"
        base = write(grid_doc(), "base.json")
        fresh = write(fresh_doc, "fresh.json")
        assert _run(monkeypatch, ["--fresh", fresh,
                                  "--baseline", base]) == 1


class TestTailInvariants:
    def test_achieved_cannot_outrun_offered(self, write, monkeypatch):
        doc = tail_doc()
        doc["entries"][0]["achieved_load"] = \
            doc["entries"][0]["offered_load"] * 1.2
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_percentiles_must_be_ordered(self, write, monkeypatch):
        doc = tail_doc()
        doc["entries"][1]["p90_us"] = 200.0          # above p99
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_count_conservation(self, write, monkeypatch):
        doc = tail_doc()
        doc["entries"][0]["missed"] = 3              # count + 3 != n_ops...
        doc["entries"][0]["miss_rate"] = 0.03
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_needs_two_offered_loads(self, write, monkeypatch):
        doc = tail_doc()
        doc["entries"][1]["offered_load"] = \
            doc["entries"][0]["offered_load"]
        assert _run(monkeypatch, [write(doc)]) == 1


class TestClusterInvariants:
    def test_shares_must_sum_to_one(self, write, monkeypatch):
        doc = cluster_doc()
        doc["entries"][0]["nodes"][0]["share"] = 0.7
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_node_records_match_n_nodes(self, write, monkeypatch):
        doc = cluster_doc()
        del doc["entries"][0]["nodes"][1]
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_degraded_scenario_required(self, write, monkeypatch):
        doc = cluster_doc()
        for agg in doc["summary"].values():
            agg["degraded_nodes"] = []
        for e in doc["entries"]:
            for n in e["nodes"]:
                n["degraded"] = False
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_migrate_exempts_per_node_bound(self, write, monkeypatch):
        doc = cluster_doc()
        for e in doc["entries"]:
            if e["name"] != "degraded_node":
                continue
            e["migrate"] = True
            e["nodes"][0]["achieved_load"] = \
                e["nodes"][0]["offered_load"] * 2.0
        assert _run(monkeypatch, [write(doc)]) == 0
        strict = copy.deepcopy(doc)
        for e in strict["entries"]:
            e["migrate"] = False
        assert _run(monkeypatch, [write(strict, "strict.json")]) == 1


class TestSuiteSchema:
    def test_index_and_artifacts_must_agree(self, write, monkeypatch):
        doc = suite_doc()
        doc["artifacts"]["extra"] = {"rows": [suite_row()]}
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_declared_row_count_checked(self, write, monkeypatch):
        doc = suite_doc()
        doc["index"][0]["n_rows"] = 5
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_rows_must_be_positive(self, write, monkeypatch):
        doc = suite_doc()
        doc["artifacts"]["flat"]["rows"][0]["throughput"] = 0.0
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_cluster_row_shares_checked(self, write, monkeypatch):
        doc = suite_doc()
        doc["artifacts"]["fleet"]["rows"][0]["nodes"][0]["share"] = 0.9
        assert _run(monkeypatch, [write(doc)]) == 1

    def test_flat_summary_fields_required(self, write, monkeypatch):
        doc = suite_doc()
        del doc["summary"]["total_rows"]
        assert _run(monkeypatch, [write(doc)]) == 1
