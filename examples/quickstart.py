"""Quickstart: the three layers of this repo in ~60 seconds on CPU.

  1. the paper's analytical model (closed form),
  2. the discrete-event "FPGA testbed" simulator validating it,
  3. the JAX framework: a tiny LM forward/train step + the paged-KV
     decode kernel (interpret mode).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.core.latency_model import (
    US, PAPER_EXAMPLE, lstar_best, lstar_mem, theta_mask_inv, theta_prob_inv,
)
from repro.core.sim import SimConfig, best_over_threads, microbenchmark_source
from repro.models.layers import init_params
from repro.train.train_step import TrainHParams, init_train_state, make_train_step
from repro.zoo import get_api

print("=== 1. the paper's model ===")
p = PAPER_EXAMPLE
print(f"memory-only tolerated latency L* = {lstar_mem(p)/US:.1f} us (Eq. 4)")
print(f"with IO                       L* = {lstar_best(p)/US:.1f} us (Eq. 8)")
for L in (1, 5, 10):
    mask = 1 / theta_mask_inv(np.array([L * US]))[0]
    prob = 1 / theta_prob_inv(np.array([L * US]))[0]
    print(f"L_mem={L:2d}us: masking-only {mask/1e3:6.1f} kops/s, "
          f"probabilistic {prob/1e3:6.1f} kops/s")

print("\n=== 2. the simulator agrees (O3) ===")
src = microbenchmark_source(10, p.T_mem, p.T_io_pre, p.T_io_post)
for L in (1, 5):
    r, n = best_over_threads(SimConfig(L_mem=L * US, P=10), src, 4000)
    prob = 1 / theta_prob_inv(np.array([L * US]))[0]
    print(f"L_mem={L}us: simulated {r.throughput/1e3:6.1f} kops/s "
          f"(model {prob/1e3:6.1f}, best N={n})")

print("\n=== 3. the framework: one train step of a tiny qwen2.5 ===")
cfg = smoke_config(ARCHS["qwen2.5-3b"])
api = get_api(cfg)
hp = TrainHParams(total_steps=10, warmup=1)
step = jax.jit(make_train_step(api, cfg, hp), donate_argnums=0)
params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
state = init_train_state(params, hp)
t = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0, cfg.vocab)
batch = {"tokens": t[:, :-1], "targets": t[:, 1:],
         "loss_mask": jnp.ones((4, 32), jnp.float32)}
state, metrics = step(state, batch)
print(f"loss={float(metrics['loss']):.3f} (ln V = {np.log(cfg.vocab):.3f})")

print("\n=== 3b. paged decode through the DMA-prefetch kernel ===")
from repro.kernels.ops import paged_decode_attention

B, Hq, Hkv, D, page, ppseq = 2, 4, 2, 32, 8, 4
kp = jax.random.normal(jax.random.PRNGKey(2), (32, page, Hkv, D), jnp.float32)
vp = jax.random.normal(jax.random.PRNGKey(3), (32, page, Hkv, D), jnp.float32)
bt = jnp.arange(B * ppseq, dtype=jnp.int32).reshape(B, ppseq)
q = jax.random.normal(jax.random.PRNGKey(4), (B, Hq, D), jnp.float32)
out = paged_decode_attention(q, kp, vp, bt, jnp.array([20, 30], jnp.int32))
print(f"paged attention out shape {out.shape}, finite={bool(jnp.all(jnp.isfinite(out)))}")
print("\nquickstart OK")
