"""The paper's story end to end: O1 -> O5 on the KV-store engines.

Run:  PYTHONPATH=src python examples/kvstore_demo.py
"""
import numpy as np

from repro.core import workloads
from repro.core.kvstore import LSMStore, TreeIndexStore, run_trace
from repro.core.latency_model import US, theta_mask_inv, theta_mem_inv, theta_prob_inv
from repro.core.simulator import SimConfig, best_over_threads, microbenchmark_source, trace_source
from repro.core.tiering import FLASH_CXL

print("O1: even with prefetching, memory-only traversal slows down:")
src = microbenchmark_source(10, 0.1 * US, 0, 0, n_io=0)
for L in (1, 5):
    r, _ = best_over_threads(SimConfig(L_mem=L * US, P=10), src, 4000)
    print(f"  L={L}us: {r.throughput/1e3:7.1f} kops/s")

print("O2/O3: IO makes the same traversal latency-tolerant:")
src = microbenchmark_source(10, 0.1 * US, 4 * US, 3 * US)
base = None
for L in (0.1, 5):
    r, _ = best_over_threads(SimConfig(L_mem=L * US, P=10), src, 4000)
    base = base or r.throughput
    print(f"  L={L}us: {r.throughput/1e3:7.1f} kops/s "
          f"({r.throughput/base:.0%} of DRAM)")

print("O4: a real engine (tree index + SSD values), model vs 'measurement':")
store = TreeIndexStore(100_000, seed=1)
wl = workloads.uniform(100_000, 30_000, (1, 0), seed=2)
tr = run_trace(store, wl)
p = tr.op_params(store.times, P=12, T_sw=0.05 * US)
src = trace_source(tr.ops)
print(f"  measured: M={p.M:.1f} hops/op, S={p.S:.2f} IOs/op")
for L in (0.1, 5.0):
    r, _ = best_over_threads(SimConfig(L_mem=L * US, P=12), src, 5000)
    prob = 1 / theta_prob_inv(np.array([L * US]), p)[0]
    mask = 1 / theta_mask_inv(np.array([L * US]), p)[0]
    print(f"  L={L}us: sim {r.throughput/1e3:7.1f}k  "
          f"Theta_prob {prob/1e3:7.1f}k  Theta_mask {mask/1e3:7.1f}k")

print("O5 + Sec 5.1: flash-like tail latency (5/14/48us), still near-DRAM:")
r_dram, _ = best_over_threads(SimConfig(L_mem=0.1 * US, P=12), src, 5000)
r_tail, _ = best_over_threads(
    SimConfig(L_mem=FLASH_CXL.latency_spec(), P=12), src, 5000)
print(f"  DRAM {r_dram.throughput/1e3:.1f}k vs flash-tail "
      f"{r_tail.throughput/1e3:.1f}k "
      f"-> degradation {1 - r_tail.throughput/r_dram.throughput:.1%} "
      f"(paper: 2-19%)")
