"""The paper's story end to end: O1 -> O5 on the KV-store engines.

Run:  PYTHONPATH=src python examples/kvstore_demo.py
"""
import numpy as np

from repro.core import workloads
from repro.core.engines import LSMStore, TreeIndexStore, create_engine, run_trace
from repro.core.experiment import Experiment, RunArtifact, Scenario
from repro.core.latency_model import US, theta_mask_inv, theta_prob_inv
from repro.core.sim import SimConfig, microbenchmark_source, sweep_latency
from repro.core.tiering import FLASH_CXL

print("O1: even with prefetching, memory-only traversal slows down:")
src = microbenchmark_source(10, 0.1 * US, 0, 0, n_io=0)
for pt in sweep_latency(SimConfig(P=10), src, [1 * US, 5 * US], n_ops=4000):
    print(f"  L={pt.L_mem / US:.0f}us: {pt.throughput / 1e3:7.1f} kops/s")

print("O2/O3: IO makes the same traversal latency-tolerant:")
src = microbenchmark_source(10, 0.1 * US, 4 * US, 3 * US)
base = None
for pt in sweep_latency(SimConfig(P=10), src, [0.1 * US, 5 * US], n_ops=4000):
    base = base or pt.throughput
    print(f"  L={pt.L_mem / US:.1f}us: {pt.throughput / 1e3:7.1f} kops/s "
          f"({pt.throughput / base:.0%} of DRAM)")

print("O4: a real engine (tree index + SSD values), model vs 'measurement':")
store = TreeIndexStore(100_000, seed=1)
wl = workloads.uniform(100_000, 30_000, (1, 0), seed=2)
tr = run_trace(store, wl)           # one compiled columnar trace ...
p = tr.op_params(store.times, P=12, T_sw=0.05 * US)
print(f"  measured: M={p.M:.1f} hops/op, S={p.S:.2f} IOs/op")
# ... shared by every cell of the latency x threads sweep grid:
for pt in sweep_latency(SimConfig(P=12), tr.trace, [0.1 * US, 5.0 * US],
                        n_ops=5000):
    L = np.array([pt.L_mem])
    prob = 1 / theta_prob_inv(L, p)[0]
    mask = 1 / theta_mask_inv(L, p)[0]
    print(f"  L={pt.L_mem / US:.1f}us: sim {pt.throughput / 1e3:7.1f}k  "
          f"Theta_prob {prob / 1e3:7.1f}k  Theta_mask {mask / 1e3:7.1f}k")

print("O5 + Sec 5.1: flash-like tail latency (5/14/48us), still near-DRAM:")
r_dram, r_tail = sweep_latency(
    SimConfig(P=12), tr.trace, [0.1 * US, FLASH_CXL.latency_spec()],
    n_ops=5000)
print(f"  DRAM {r_dram.throughput / 1e3:.1f}k vs flash-tail "
      f"{r_tail.throughput / 1e3:.1f}k "
      f"-> degradation {1 - r_tail.throughput / r_dram.throughput:.1%} "
      f"(paper: 2-19%)")

print("O6: the engine x device matrix -- any registered engine against any")
print("    SSD pool (per-device IOPS token clocks, switch fan-out hop):")
hstore = create_engine("hash-index", 50_000, seed=6)
htr = run_trace(hstore, workloads.uniform(50_000, 20_000, (1, 0), seed=2))
for n_ssd in (1, 2):
    cfg = SimConfig(P=12, R_io=250e3, n_ssd=n_ssd,
                    L_switch=0.3 * US if n_ssd > 1 else 0.0)
    pts = sweep_latency(cfg, htr.trace, [0.1 * US, 10 * US], n_ops=4000)
    thr = [pt.throughput / 1e3 for pt in pts]
    print(f"  hash-index x {n_ssd} SSD: {thr[0]:6.1f}k -> {thr[1]:6.1f}k "
          f"at 10us ({thr[1] / thr[0]:.0%} kept)")

print("O7: the whole protocol as one declarative, serializable scenario")
print("    (the public experiment API; same spec format as")
print("    examples/scenarios/*.json and `benchmarks.run --scenario`):")
scenario = Scenario(
    engine="slab-cache",                  # any registry name or alias
    workload="zipf",                      # any workload-registry name
    workload_kwargs={"exponent": 0.9, "read_write": (3, 1), "seed": 8},
    n_keys=30_000, n_wl_ops=12_000,
    n_ssd=2, R_io=250e3, L_switch_us=0.3,
    latencies_us=(0.1, 5, 10), thread_candidates=(16, 32, 48), n_ops=3000,
)
art = Experiment(scenario).run()          # trace once, sweep, model-compare
art = RunArtifact.from_json(art.to_json())   # artifacts round-trip as JSON
print(f"  {art.engine} x {scenario.n_ssd} SSD: S={art.S:.2f} IOs/op, "
      f"M={art.M:.1f} hops/op")
for row, norm in zip(art.rows, art.normalized()):
    print(f"  {row.label():>8}: sim {row.throughput / 1e3:7.1f}k "
          f"({norm:.0%} of DRAM)  model {row.model_throughput / 1e3:7.1f}k "
          f"[N={row.n_threads}]")
