"""End-to-end driver: train a ~100M-parameter dense LM on synthetic data.

The full run (300 steps, global batch 8 x 256 tokens) takes a while on one
CPU core; ``--steps`` shortens it. Demonstrates the whole training stack:
data pipeline -> sharded/jit train step -> AdamW -> async checkpointing ->
restart-safe loop. Resume works: re-running continues from the last
checkpoint.

Run:  PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import math
import time

import jax

from repro.configs.base import ModelConfig
from repro.roofline.analysis import count_params
from repro.train.train_step import TrainHParams
from repro.train.trainer import Trainer
from repro.zoo import get_api

CFG_100M = ModelConfig(
    name="dense-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab=8192,
    tie_embeddings=True,
).resolve()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    api = get_api(CFG_100M)
    n = count_params(api.param_specs(CFG_100M))
    print(f"model: {n/1e6:.1f}M params "
          f"({CFG_100M.n_layers}L x {CFG_100M.d_model}d, vocab {CFG_100M.vocab})")

    hp = TrainHParams(peak_lr=6e-4, warmup=max(args.steps // 20, 5),
                      total_steps=args.steps)
    tr = Trainer(CFG_100M, hp, ckpt_dir=args.ckpt, ckpt_every=50)
    tr.hp_global_batch, tr.hp_seq_len = args.batch, args.seq

    t0 = time.time()
    state, log = tr.fit(args.steps)
    if not log:
        print("nothing to do (already trained to --steps; delete --ckpt to redo)")
        return
    wall = time.time() - t0
    tokens = args.batch * args.seq * len(log)
    print(f"\ntrained {len(log)} steps, {tokens/1e3:.0f}k tokens, "
          f"{wall:.0f}s ({tokens/wall:.0f} tok/s)")
    k = max(len(log) // 12, 1)
    for i in range(0, len(log), k):
        m = log[i]
        print(f"  step {i:4d}  loss {float(m.get('loss', 0)):6.3f}  "
              f"gnorm {float(m.get('grad_norm', 0)):6.2f}")
    first = sum(float(m["loss"]) for m in log[:5]) / min(5, len(log))
    last = sum(float(m["loss"]) for m in log[-5:]) / min(5, len(log))
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"(floor ~= noise entropy {0.25 * math.log(CFG_100M.vocab):.2f}+)")


if __name__ == "__main__":
    main()
