"""Serve a small model with batched requests over the tiered paged KV cache.

The page store is the "microsecond-latency memory" of the paper; decode
attention reaches it only through the DMA-prefetch kernel, and the prefetch
depth is sized by the paper's Theta model for the configured tier latency.

Run:  PYTHONPATH=src python examples/serve_tiered_kv.py
"""
import time

import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.core.tiering import CXL_MICROSECOND, TPU_HOST
from repro.serve.engine import Request, ServeEngine

cfg = smoke_config(ARCHS["qwen2.5-3b"]).replace(sliding_window=None)
eng = ServeEngine(cfg, n_pages=128, page_size=8, max_slots=4, seed=0)

rng = np.random.default_rng(0)
reqs = [
    Request(rid=i, prompt=rng.integers(1, cfg.vocab, rng.integers(4, 20)).astype(np.int32),
            max_new_tokens=8)
    for i in range(10)
]
for r in reqs:
    eng.submit(r)

t0 = time.time()
done = eng.run(max_steps=400)
wall = time.time() - t0
tokens = sum(len(r.out_tokens) for r in done)
print(f"served {len(done)} requests, {tokens} tokens in {eng.steps} engine "
      f"steps ({wall:.1f}s on CPU-interpret)")
print(f"page utilization at end: {eng.cache.utilization:.0%} "
      f"(all pages released: {len(eng.cache.free) == eng.cache.cfg.n_pages})")

# model-driven prefetch depth for two slow-tier choices
eng.cache.admit(999, 64)
for tier in (TPU_HOST, CXL_MICROSECOND):
    eng.cache.cfg = eng.cache.cfg.__class__(**{**eng.cache.cfg.__dict__, "tier": tier})
    depth = eng.cache.plan_prefetch_depth(t_page_compute=2e-6, t_step_other=30e-6)
    print(f"planned DMA prefetch depth for {tier.name} "
          f"(L={tier.latency*1e6:.1f}us): P={depth}")
eng.cache.release(999)
print("first request sample output:", done[0].out_tokens)
